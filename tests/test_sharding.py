"""Sharding rules + multi-device SPMD correctness (8 fake CPU devices in a
subprocess, since the main test process is pinned to 1 device)."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro import configs
from repro.models import registry
from repro.param import is_spec
from repro.sharding import PRESETS, resolve_spec

MESH_AXES = ("data", "model")


def test_resolve_spec_basics():
    from jax.sharding import PartitionSpec as P
    rules = PRESETS["fsdp_tp"]
    assert resolve_spec(("embed", "mlp"), rules, MESH_AXES) == P("data", "model")
    assert resolve_spec(("layers", "embed", "heads"), rules, MESH_AXES) == \
        P(None, "data", "model")
    assert resolve_spec((None,), rules, MESH_AXES) == P()
    # pod axis dropped on single-pod mesh
    assert resolve_spec(("batch", None), rules, MESH_AXES) == P("data")
    # no mesh axis used twice
    assert resolve_spec(("mlp", "heads"), rules, MESH_AXES) == P("model")


def test_presets_differ():
    from jax.sharding import PartitionSpec as P
    assert resolve_spec(("embed",), PRESETS["dp"], MESH_AXES) == P()
    assert resolve_spec(("embed",), PRESETS["fsdp"], MESH_AXES) == P("data")
    assert resolve_spec(("mlp",), PRESETS["tp"], MESH_AXES) == P("model")
    assert resolve_spec(("batch",), PRESETS["fsdp_tp_long"], MESH_AXES) == P()


@pytest.mark.parametrize("arch", configs.ASSIGNED)
def test_all_params_divisible_on_production_mesh(arch):
    """Every weight dim a rule shards must divide by its mesh axes — this is
    the static guarantee behind the 40-cell dry-run."""
    cfg = configs.get(arch)
    sizes = {"data": 16, "model": 16, "pod": 2}
    rules = PRESETS["fsdp_tp"]
    import jax
    for path, s in jax.tree_util.tree_flatten_with_path(
            registry.param_specs(cfg), is_leaf=is_spec)[0]:
        pspec = resolve_spec(s.axes, rules, ("pod",) + MESH_AXES)
        for dim, entry in zip(s.shape, tuple(pspec) + (None,) * 8):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            denom = int(np.prod([sizes[a] for a in axes]))
            assert dim % denom == 0, (arch, path, s.shape, pspec)


def test_zero_bytes_accounting():
    """C1: FSDP frees (1 - 1/shards) of parameter memory per device."""
    import jax
    from jax.sharding import Mesh
    from repro.core.zero import bytes_per_device
    cfg = configs.get("qwen15_05b")
    specs = registry.param_specs(cfg)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), MESH_AXES)
    full = bytes_per_device(specs, mesh, "dp")

    class FakeMesh:
        axis_names = MESH_AXES
        devices = np.empty((16, 16))
    sharded = bytes_per_device(specs, FakeMesh(), "fsdp_tp")
    assert sharded < full / 100  # ~1/256 + replicated norms


_MULTIDEV_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
import numpy as np

import sys
sys.path.insert(0, __SRC__)
from repro import configs
from repro.config import TrainConfig
from repro.core.step import init_state, make_train_step
from repro.models import registry
from repro.sharding import shardings_for_specs
from repro.core.zero import place_params

cfg = configs.get_smoke("qwen15_05b")
tcfg = TrainConfig(global_batch=4, seq_len=8, compute_dtype="float32",
                   microbatches=2, remat_policy="full",
                   shard_preset="fsdp_tp", total_steps=3, warmup_steps=0,
                   learning_rate=1e-3)
batch = registry.make_batch(jax.random.PRNGKey(1), cfg, 4, 8)

# single-device reference
state = init_state(jax.random.PRNGKey(0), cfg, tcfg)
step = jax.jit(make_train_step(cfg, tcfg))
s_ref = state
for _ in range(2):
    s_ref, m_ref = step(s_ref, batch)

# 8-device (2 data x 4 model) SPMD
mesh = jax.make_mesh((2, 4), ("data", "model"))
with mesh:
    state2 = init_state(jax.random.PRNGKey(0), cfg, tcfg)
    from repro.core.step import state_specs
    sspecs = state_specs(cfg, tcfg)
    sh = shardings_for_specs(sspecs, mesh, "fsdp_tp")
    state2 = jax.tree.map(jax.device_put, state2,
                          jax.tree.unflatten(jax.tree.structure(state2),
                                             jax.tree.leaves(sh)))
    batch2 = jax.device_put(batch, NamedSharding(mesh, P("data")))
    step2 = jax.jit(make_train_step(cfg, tcfg))
    s2 = state2
    for _ in range(2):
        s2, m2 = step2(s2, batch2)

# param distributed across devices?
w = s2["params"]["blocks"]["attn"]["wq"]
n_shards = len({d for d in w.sharding.device_set})
print(json.dumps({
    "loss_ref": float(m_ref["loss"]), "loss_spmd": float(m2["loss"]),
    "gnorm_ref": float(m_ref["grad_norm"]), "gnorm_spmd": float(m2["grad_norm"]),
    "n_shard_devices": n_shards,
}))
"""


@pytest.mark.slow
def test_spmd_matches_single_device(tmp_path):
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    script = _MULTIDEV_SCRIPT.replace("__SRC__", repr(os.path.abspath(src)))
    p = tmp_path / "spmd_check.py"
    p.write_text(script)
    env = dict(os.environ)
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run([sys.executable, str(p)], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["n_shard_devices"] == 8
    np.testing.assert_allclose(res["loss_spmd"], res["loss_ref"], rtol=1e-4)
    np.testing.assert_allclose(res["gnorm_spmd"], res["gnorm_ref"], rtol=1e-3)
