"""Fixture-level tests for the concurrency lint (tools.repro_analysis.lint).

Each rule gets a minimal firing fixture and a minimal passing one,
including reproductions of the exact pre-fix patterns the rules were
built from: the PR 5 silent-writer-death thread body and unguarded
touches of ``# guarded-by`` fields.  The final test asserts the real
tree is clean — the CI gate in test form.
"""
import os
import textwrap

from tools.repro_analysis.lint import lint_source, run_lint

REPO = os.path.join(os.path.dirname(__file__), "..")


def _codes(src, path="<fixture>", select=None):
    return [v.code for v in lint_source(textwrap.dedent(src), path, select)]


# ---------------------------------------------------------------------------
# RA001 — guarded-by lock discipline
# ---------------------------------------------------------------------------

GUARDED_HEADER = """
import threading

class Box:
    def __init__(self):
        self._lock = threading.Condition()
        self._pending = []   # guarded-by: _lock
"""


def test_ra001_fires_on_unguarded_touch():
    src = GUARDED_HEADER + """
    def poke(self):
        self._pending.append(1)
"""
    assert _codes(src) == ["RA001"]


def test_ra001_prefix_guarded_field_pattern():
    # the pre-fix shape RA001 exists for: an error field declared guarded
    # but read on the submit path without taking the lock first
    src = GUARDED_HEADER.replace("_pending = []   ",
                                 "_error = None   ") + """
    def submit(self):
        if self._error is not None:
            raise RuntimeError("boom") from self._error
"""
    assert _codes(src) == ["RA001", "RA001"]


def test_ra001_passes_inside_with_lock():
    src = GUARDED_HEADER + """
    def poke(self):
        with self._lock:
            self._pending.append(1)
"""
    assert _codes(src) == []


def test_ra001_init_is_exempt_and_holds_honored():
    src = GUARDED_HEADER + """
    def _drain(self):   # holds: _lock
        self._pending.clear()

    def poke(self):
        with self._lock:
            self._drain()
"""
    assert _codes(src) == []


def test_ra001_waiver():
    src = GUARDED_HEADER + """
    def peek(self):
        return len(self._pending)  # unguarded-ok: racy len is fine here
"""
    assert _codes(src) == []


# ---------------------------------------------------------------------------
# RA002 — thread lifecycle
# ---------------------------------------------------------------------------

def test_ra002_fires_on_prefix_checkpoint_save_async():
    # the exact pre-fix CheckpointStore.save_async shape: the background
    # writer has a join (wait()) but no exception-surfacing try/except —
    # a failed save vanished with its thread
    src = """
import threading

class Store:
    def __init__(self):
        self._thread = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save_async(self, state):
        self.wait()

        def _write():
            save(state)

        self._thread = threading.Thread(target=_write, daemon=False)
        self._thread.start()
"""
    assert _codes(src, select=["RA002"]) == ["RA002"]


def test_ra002_passes_on_surfacing_pattern():
    src = """
import threading

class Store:
    def __init__(self):
        self._thread = None
        self._error = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            raise RuntimeError() from self._error

    def save_async(self, state):
        def _write():
            try:
                save(state)
            except BaseException as e:
                self._error = e

        self._thread = threading.Thread(target=_write)
        self._thread.start()
"""
    assert _codes(src, select=["RA002"]) == []


def test_ra002_fires_without_join():
    src = """
import threading

def fire_and_forget(fn):
    t = threading.Thread(target=fn)
    t.start()
"""
    assert "RA002" in _codes(src, select=["RA002"])


def test_ra002_executor_needs_shutdown_and_waiver_works():
    bad = """
from concurrent.futures import ThreadPoolExecutor

class W:
    def __init__(self):
        self._pool = ThreadPoolExecutor(max_workers=1)
"""
    assert _codes(bad, select=["RA002"]) == ["RA002"]
    good = bad + """
    def close(self):
        self._pool.shutdown(wait=True)
"""
    assert _codes(good, select=["RA002"]) == []
    waived = bad.replace(
        "ThreadPoolExecutor(max_workers=1)",
        "ThreadPoolExecutor(max_workers=1)  # thread-ok: process-lifetime")
    assert _codes(waived, select=["RA002"]) == []


# ---------------------------------------------------------------------------
# RA003 — host syncs in hot paths
# ---------------------------------------------------------------------------

def test_ra003_fires_on_sync_in_hot_path():
    src = """
class Step:
    def run(self, x):  # hot-path
        return float(x)
"""
    assert _codes(src, select=["RA003"]) == ["RA003"]


def test_ra003_sync_point_waiver_and_cold_path_ignored():
    src = """
import numpy as np

class Step:
    def run(self, x):  # hot-path
        return float(x)  # sync-point: end-of-step metric

    def report(self, x):
        return np.asarray(x)
"""
    assert _codes(src, select=["RA003"]) == []


def test_ra003_designated_functions_must_be_annotated():
    # deleting the # hot-path comment on a designated function is itself
    # a violation — the rule cannot be silently dropped
    src = """
class StreamedTrainStep:
    def _sink(self, seg):
        pass
"""
    codes = _codes(src, path="src/repro/core/stream.py", select=["RA003"])
    assert codes == ["RA003"]


# ---------------------------------------------------------------------------
# RA004 — donated-argument reuse
# ---------------------------------------------------------------------------

DONATING = """
import jax

step = jax.jit(_step, donate_argnums=(0,))
"""


def test_ra004_fires_on_reuse_after_donation():
    src = DONATING + """
def run(state, batch):
    out = step(state, batch)
    return state
"""
    assert _codes(src, select=["RA004"]) == ["RA004"]


def test_ra004_rebinding_is_safe():
    src = DONATING + """
def run(state, batch):
    state = step(state, batch)
    return state
"""
    assert _codes(src, select=["RA004"]) == []


def test_ra004_loop_wraparound_fires():
    src = DONATING + """
def run(state, batches):
    for b in batches:
        step(state, b)
"""
    assert _codes(src, select=["RA004"]) == ["RA004"]


def test_ra004_other_scope_binding_not_confused():
    # step_fn is donating in one function and a plain callable in another
    # — the registry is scope-aware, so the second function is clean
    src = """
import jax

def bench_jit(state, batch):
    step_fn = jax.jit(_step, donate_argnums=(0,))
    state = step_fn(state, batch)
    return state

def bench_stream(state, batch):
    step_fn = make_streamed_step()
    step_fn(state, batch)
    return state
"""
    assert _codes(src, select=["RA004"]) == []


def test_ra004_waiver():
    src = DONATING + """
def run(state, batch):
    out = step(state, batch)  # donate-ok
    return state
"""
    assert _codes(src, select=["RA004"]) == []


# ---------------------------------------------------------------------------
# the real tree is clean (the CI gate, in test form)
# ---------------------------------------------------------------------------

def test_repo_tree_is_clean():
    paths = [os.path.join(REPO, p) for p in ("src", "tests", "benchmarks")]
    violations = run_lint(paths)
    assert violations == [], "\n".join(str(v) for v in violations)
