import os
import sys

# tests run on ONE device (the dry-run sets its own 512-device flag in a
# subprocess); keep determinism + quiet logs
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# repo root: the concurrency-tooling tests import ``tools.repro_analysis``
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax  # noqa: E402

jax.config.update("jax_default_matmul_precision", "highest")


def hypothesis_or_stub():
    """Import hypothesis, or return (stub, stub) whose ``@given`` marks the
    decorated test skipped — mixed test modules keep their plain tests
    runnable without the optional dep."""
    try:
        import hypothesis
        import hypothesis.strategies as st
        return hypothesis, st
    except ImportError:
        import pytest

        class _St:
            def __getattr__(self, name):
                return lambda *a, **kw: None

        class _Hyp:
            @staticmethod
            def settings(**kw):
                return lambda f: f

            @staticmethod
            def given(*a, **kw):
                return lambda f: pytest.mark.skip(
                    "hypothesis not installed")(f)

        return _Hyp(), _St()
