"""Pluggable raw segment-read backends (offload/readers.py).

Covers: per-backend bit-identity against the mmap oracle across every
codec and read mode (decoded / window / encoded / out=readinto), the
aligned buffer pool and its O_DIRECT alignment contract, probe-gated
fallback resolution (O_DIRECT-unsupported filesystem, absent io_uring),
EOF zero-fill parity with sparse mmap holes, the ``REPRO_OFFLOAD_IO``
env override, the ``copy=False`` view-lifetime debug guard, the
``copy_file_range`` COW break + ``cow_break_s`` stat, and per-backend
async-vs-sync loss equality on the streamed trainer.
"""
import gc
import os

import numpy as np
import pytest

from repro.offload import readers
from repro.offload.readers import (ALIGN, AlignedBufferPool, aligned_empty,
                                   backend_available, is_aligned,
                                   resolve_io_backend)
from repro.offload.segments import SegmentStore, _copy_file

RAW_BACKENDS = ("pread", "direct", "uring")


def _need(backend, directory):
    if not backend_available(backend, str(directory)):
        pytest.skip(f"{backend} unsupported on this kernel/filesystem")


def _codec_groups(seed=0):
    """One group exercising every codec, including a 0-d scalar leaf and a
    bf16 leaf (flat window reads) — the mix that tells flat-into-dst reads
    apart from staged decodes."""
    rng = np.random.RandomState(seed)
    return [[("p.w", rng.randn(6, 5).astype(np.float32)),
             ("p.scalar", np.float32(rng.randn())),
             ("m.w", rng.randn(6, 5).astype(np.float32), "bf16"),
             ("q.w", rng.randn(8, 4).astype(np.float32), "int8"),
             ("a.w", rng.randn(3, 7).astype(np.float32), "act_int8")],
            [("p2.w", rng.randn(16, 3).astype(np.float32)),
             ("m2.w", rng.randn(16, 3).astype(np.float32), "bf16")]]


def _assert_named_equal(got, want, ctx=""):
    assert set(got) == set(want), ctx
    for name in want:
        g, w = got[name], want[name]
        if hasattr(w, "codes"):                     # QuantLeaf
            np.testing.assert_array_equal(g.codes, w.codes, err_msg=ctx)
            np.testing.assert_array_equal(g.scales, w.scales, err_msg=ctx)
        else:
            assert g.dtype == w.dtype, (ctx, name, g.dtype, w.dtype)
            np.testing.assert_array_equal(g, w, err_msg=f"{ctx}:{name}")


# ---------------------------------------------------------------------------
# bit-identity vs the mmap oracle, all codecs x all read modes
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", RAW_BACKENDS)
def test_backend_bit_identical_to_mmap(backend, tmp_path):
    _need(backend, tmp_path)
    store = SegmentStore.create(str(tmp_path / "s"), _codec_groups(), 2)
    oracle = [{mode: store.read_segment(seg, **kw)
               for mode, kw in (("decoded", {}), ("window", {"window": True}),
                                ("encoded", {"encoded": True}))}
              for seg in range(2)]
    assert store.io_backend == "mmap"
    assert store.set_io_backend(backend) == backend
    for seg in range(2):
        for mode, kw in (("decoded", {}), ("window", {"window": True}),
                         ("encoded", {"encoded": True})):
            _assert_named_equal(store.read_segment(seg, **kw),
                                oracle[seg][mode], f"{backend}/{mode}/{seg}")
    s = store.io_stats()
    assert s["io_bytes_read"] > 0
    assert s["io_fallbacks"] == 0, f"{backend} silently degraded: {s}"
    store.close_io()


@pytest.mark.parametrize("backend", RAW_BACKENDS)
def test_backend_reads_after_write(backend, tmp_path):
    """Raw reads observe bytes written through the (mmap/pwrite) write
    path — one unified view of the segment file."""
    _need(backend, tmp_path)
    store = SegmentStore.create(str(tmp_path / "s"), _codec_groups(), 2,
                                io_backend=backend)
    fresh = {"p.w": np.full((6, 5), 3.25, np.float32)}
    store.write_segment(0, fresh)
    assert np.array_equal(store.read_segment(0)["p.w"], fresh["p.w"])
    store.pwrite_segment(0, {"p.w": np.full((6, 5), -1.5, np.float32)})
    store.sync_segment(0)
    assert (store.read_segment(0)["p.w"] == -1.5).all()
    store.close_io()


@pytest.mark.parametrize("backend", RAW_BACKENDS)
def test_sparse_scratch_reads_zeros(backend, tmp_path):
    """write=False stores are sparse; a raw read past the written extent
    must zero-fill exactly like an mmap hole."""
    _need(backend, tmp_path)
    store = SegmentStore.create(str(tmp_path / "s"), _codec_groups(), 2,
                                write=False, io_backend=backend)
    for seg in range(2):
        for arr in store.read_segment(seg).values():
            assert not np.asarray(arr).any()
    store.close_io()


# ---------------------------------------------------------------------------
# out= readinto path: reuse, alignment, mismatch fallback
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", RAW_BACKENDS)
def test_out_buffers_filled_in_place(backend, tmp_path):
    _need(backend, tmp_path)
    store = SegmentStore.create(str(tmp_path / "s"), _codec_groups(), 2,
                                io_backend=backend)
    want = SegmentStore.open(str(tmp_path / "s")).read_segment(0,
                                                               window=True)
    leaves = [store.record(n) for n in store.segment_names(0)]
    outs = [aligned_empty(r.shape, w.dtype)
            for r, w in zip(leaves, (want[r.name] for r in leaves))]
    got = store.read_segment(0, window=True, out=outs)
    from repro.offload.codecs import get_codec
    for i, r in enumerate(leaves):
        if get_codec(r.codec).storage_np_dtype(r.dtype) is not None:
            # flat-storage leaves (identity, bf16 windows) fill in place;
            # packed int8 leaves allocate — same contract as the mmap path
            assert got[r.name] is outs[i], f"leaf {r.name} was not read into"
        np.testing.assert_array_equal(got[r.name], want[r.name])
    store.close_io()


def test_out_mismatch_falls_back_to_allocation(tmp_path):
    store = SegmentStore.create(str(tmp_path / "s"), _codec_groups(), 2,
                                io_backend="pread")
    want = store.read_segment(0)
    n = len(store.segment_names(0))
    # non-contiguous (flat read path needs contiguity), wrong shape, wrong
    # dtype, and None entries must all be ignored (allocation fallback),
    # never corrupted or crashed into
    bad = [np.empty((6, 10), np.float32)[:, ::2],    # p.w: non-contiguous
           np.empty((2, 2), np.float32),             # p.scalar: wrong shape
           np.empty((6, 5), np.float64)] + [None] * (n - 3)  # m.w: dtype
    got = store.read_segment(0, out=bad)
    _assert_named_equal(got, want, "mismatched out")
    for b in bad[:3]:
        assert all(got[name] is not b for name in got)
    store.close_io()


def test_aligned_pool_contract():
    assert is_aligned(aligned_empty((3, 5), np.float32))
    assert aligned_empty((), np.float32).shape == ()
    pool = AlignedBufferPool(max_buffers=2)
    a = pool.get(100)
    assert a.nbytes == ALIGN and is_aligned(a)     # capacity class rounds up
    assert pool.pool_bytes() == ALIGN              # lent counts
    pool.put(a)
    b = pool.get(50)
    assert b is a and pool.reuses == 1             # size-classed reuse
    pool.put(b)
    for buf in [pool.get(ALIGN) for _ in range(4)]:
        pool.put(buf)                              # bound: extras dropped
    assert pool.pool_bytes() <= 2 * ALIGN


# ---------------------------------------------------------------------------
# resolution: explicit > env > mmap; probe-gated fallbacks
# ---------------------------------------------------------------------------
def test_env_var_override(tmp_path, monkeypatch):
    store = SegmentStore.create(str(tmp_path / "s"), _codec_groups(), 2)
    monkeypatch.setenv(readers.ENV_VAR, "pread")
    re = SegmentStore.open(str(tmp_path / "s"))
    assert (re.io_requested, re.io_backend) == ("pread", "pread")
    # explicit argument wins over the env var
    assert SegmentStore.open(str(tmp_path / "s"),
                             io_backend="mmap").io_backend == "mmap"
    re.close_io()


def test_unknown_backend_rejected(tmp_path):
    with pytest.raises(ValueError, match="unknown offload I/O backend"):
        resolve_io_backend("sendfile", str(tmp_path))


def test_direct_unsupported_falls_back_to_pread(tmp_path, monkeypatch):
    monkeypatch.setattr(readers, "direct_supported", lambda d: False)
    store = SegmentStore.create(str(tmp_path / "s"), _codec_groups(), 2,
                                io_backend="direct")
    assert (store.io_requested, store.io_backend) == ("direct", "pread")
    _assert_named_equal(store.read_segment(0),
                        SegmentStore.open(store.directory).read_segment(0))
    store.close_io()


def test_uring_probe_absent_falls_back_to_pread(tmp_path, monkeypatch):
    monkeypatch.setattr(readers, "uring_supported", lambda: False)
    store = SegmentStore.create(str(tmp_path / "s"), _codec_groups(), 2,
                                io_backend="uring")
    assert (store.io_requested, store.io_backend) == ("uring", "pread")
    _assert_named_equal(store.read_segment(0),
                        SegmentStore.open(store.directory).read_segment(0))
    store.close_io()


def test_auto_probes_to_some_raw_backend(tmp_path):
    req, actual = resolve_io_backend("auto", str(tmp_path))
    assert req == "auto" and actual in ("uring", "direct", "pread")


def test_copy_false_always_uses_mmap(tmp_path):
    """Zero-copy views only exist on the page-cache map; a raw backend
    must not be consulted for copy=False."""
    store = SegmentStore.create(str(tmp_path / "s"), _codec_groups(), 2,
                                io_backend="pread")
    views = store.read_segment(0, copy=False)
    assert any(getattr(v, "base", None) is not None or
               isinstance(v, np.memmap) for v in views.values())
    assert store.io_stats().get("io_bytes_read", 0) == 0
    del views
    store.close_io()


# ---------------------------------------------------------------------------
# satellites: view guard, COW break, engine integration
# ---------------------------------------------------------------------------
def test_view_guard_blocks_write_over_live_views(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_OFFLOAD_VIEW_GUARD", "1")
    store = SegmentStore.create(str(tmp_path / "s"), _codec_groups(), 2)
    views = store.read_segment(0, copy=False)
    fresh = {"p.w": np.zeros((6, 5), np.float32)}
    with pytest.raises(RuntimeError, match="zero-copy view"):
        store.write_segment(0, fresh)
    store.write_segment(1, {"p2.w": np.zeros((16, 3), np.float32)})  # other seg ok
    del views
    gc.collect()
    store.write_segment(0, fresh)              # guard cleared with the views


def test_view_guard_blocks_cow_break(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_OFFLOAD_VIEW_GUARD", "1")
    store = SegmentStore.create(str(tmp_path / "s"), _codec_groups(), 2)
    store.snapshot(str(tmp_path / "snap"))
    views = store.read_segment(0, copy=False)
    with pytest.raises(RuntimeError, match="zero-copy view"):
        store.write_segment(0, {"p.w": np.zeros((6, 5), np.float32)})
    del views
    gc.collect()


def test_cow_break_stat_and_isolation(tmp_path):
    store = SegmentStore.create(str(tmp_path / "s"), _codec_groups(), 2)
    before = store.read_segment(0)["p.w"].copy()
    store.snapshot(str(tmp_path / "snap"))
    assert store.cow_breaks == 0
    store.write_segment(0, {"p.w": np.full((6, 5), 9.0, np.float32)})
    assert store.cow_breaks == 1 and store.cow_break_s > 0
    assert store.io_stats()["cow_breaks"] == 1
    snap = SegmentStore.open(str(tmp_path / "snap"))
    np.testing.assert_array_equal(snap.read_segment(0)["p.w"], before)


def test_copy_file_matches_source(tmp_path):
    src, dst = str(tmp_path / "a"), str(tmp_path / "b")
    payload = os.urandom(ALIGN * 3 + 17)       # not a block multiple
    with open(src, "wb") as f:
        f.write(payload)
    _copy_file(src, dst)
    with open(dst, "rb") as f:
        assert f.read() == payload


@pytest.mark.parametrize("backend", RAW_BACKENDS)
def test_engine_accounts_reader_pool(backend, tmp_path):
    _need(backend, tmp_path)
    from repro.offload.engine import OffloadEngine
    store = SegmentStore.create(str(tmp_path / "s"), _codec_groups(), 2,
                                io_backend=backend)
    eng = OffloadEngine(store, max_resident=1, prefetch=True)
    eng.acquire(0)
    eng.prefetch(1)
    eng.acquire(1)
    s = eng.stats()
    eng.close()
    assert s["io_bytes_read"] > 0              # reader counters surfaced
    assert "io_pool_bytes" in s
    assert s["cow_breaks"] == 0


def test_drop_cache_runs(tmp_path):
    store = SegmentStore.create(str(tmp_path / "s"), _codec_groups(), 2,
                                io_backend="pread")
    want = store.read_segment(0)
    store.drop_cache()
    _assert_named_equal(store.read_segment(0), want, "post-drop")
    store.close_io()


# ---------------------------------------------------------------------------
# tentpole acceptance: async-vs-sync loss equality under every backend
# ---------------------------------------------------------------------------
def test_streamed_loss_identical_under_every_backend(tmp_path):
    """The read transport must never touch numerics: streamed training
    losses are bit-equal across mmap/pread/direct/uring (where probed) and
    across the sync vs async pipeline."""
    from repro import configs
    from repro.config import TrainConfig
    from repro.launch.train import train_loop

    cfg = configs.get_smoke("gpt2_124m")
    base = dict(global_batch=2, seq_len=16, learning_rate=1e-4,
                schedule="constant", warmup_steps=1,
                compute_dtype="float32", total_steps=3,
                offload_stream_params=True)

    def losses(**kw):
        _, obs = train_loop(cfg, TrainConfig(**base, **kw),
                            out_dir=None, print_fn=None)
        return [r["loss"] for r in obs.rows]

    oracle = losses(offload_io="mmap", offload_async_writeback=False,
                    offload_staging=False)
    np.testing.assert_array_equal(
        oracle, losses(offload_io="pread", offload_async_writeback=False,
                       offload_staging=False))
    for backend in ("mmap",) + RAW_BACKENDS:
        if not backend_available(backend, str(tmp_path)):
            continue
        np.testing.assert_array_equal(
            oracle, losses(offload_io=backend),
            err_msg=f"async pipeline under io={backend} diverged")
