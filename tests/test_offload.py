"""Segment-wise parameter offload (paper §4.1.1 C1, phone realization).

Covers: mapping-table planning, segment round-trip integrity, LRU dirty
write-back, double-buffered prefetch, copy-on-write snapshots (zero-copy
checkpointing), segment-wise AdamW equivalence, and the smoke-train
equivalence of `--offload-segments` against the in-memory baseline.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint.store import (is_offload_checkpoint, latest_step,
                                    restore_offload, save_offload)
from repro.config import TrainConfig
from repro.core.zero import offload_resident_bytes
from repro.models import registry
from repro.offload import (OffloadEngine, OffloadedTrainState, SegmentStore,
                           plan_segments)
from repro.optim.adamw import adamw_init, adamw_update


# ---------------------------------------------------------------------------
# planning
# ---------------------------------------------------------------------------
def test_plan_segments_contiguous_and_complete():
    sizes = [10, 200, 30, 40, 5, 100, 7, 60]
    bounds = plan_segments(sizes, 4)
    assert len(bounds) == 4
    assert bounds[0][0] == 0 and bounds[-1][1] == len(sizes)
    for (a0, a1), (b0, b1) in zip(bounds, bounds[1:]):
        assert a1 == b0           # contiguous
        assert a1 > a0            # non-empty


def test_plan_segments_never_exceeds_group_count():
    assert plan_segments([100], 8) == [(0, 1)]
    assert plan_segments([1, 2], 8) == [(0, 1), (1, 2)]
    assert plan_segments([], 4) == []


def test_plan_segments_balances_bytes():
    sizes = [64] * 32
    bounds = plan_segments(sizes, 4)
    per_seg = [sum(sizes[a:b]) for a, b in bounds]
    assert max(per_seg) == min(per_seg) == sum(sizes) // 4


# ---------------------------------------------------------------------------
# segment store round trip
# ---------------------------------------------------------------------------
def _groups(seed=0, n=5, shape=(7, 3)):
    rng = np.random.RandomState(seed)
    return [[(f"p.l{i}", rng.randn(*shape).astype(np.float32)),
             (f"m.l{i}", rng.randn(*shape).astype(np.float32)),
             (f"v.l{i}", np.abs(rng.randn(*shape)).astype(np.float32))]
            for i in range(n)]


def test_segment_roundtrip_integrity(tmp_path):
    groups = _groups()
    store = SegmentStore.create(str(tmp_path / "s"), groups, 3)
    flat = {n: a for g in groups for n, a in g}
    seen = set()
    for seg in range(store.num_segments):
        for name, arr in store.read_segment(seg).items():
            np.testing.assert_array_equal(arr, flat[name])
            seen.add(name)
    assert seen == set(flat)
    # groups are never split across segments
    for g in groups:
        segs = {store.record(n).segment for n, _ in g}
        assert len(segs) == 1
    # reopen from the mapping table alone
    re = SegmentStore.open(store.directory)
    assert re.seg_nbytes == store.seg_nbytes
    for seg in range(re.num_segments):
        for name, arr in re.read_segment(seg).items():
            np.testing.assert_array_equal(arr, flat[name])


def test_read_segment_zero_copy_views(tmp_path):
    store = SegmentStore.create(str(tmp_path / "s"), _groups(n=2), 1)
    views = store.read_segment(0, copy=False)
    for arr in views.values():
        assert isinstance(arr, np.memmap) or arr.base is not None


# ---------------------------------------------------------------------------
# engine: LRU window, dirty write-back, prefetch
# ---------------------------------------------------------------------------
def test_lru_eviction_writes_back_dirty(tmp_path):
    store = SegmentStore.create(str(tmp_path / "s"), _groups(), 3)
    eng = OffloadEngine(store, max_resident=1, prefetch=False)
    d0 = eng.acquire(0)
    name = next(iter(d0))
    d0[name][...] = 7.5
    eng.mark_dirty(0)
    eng.acquire(1)                     # evicts 0 -> write-back
    fresh = SegmentStore.open(store.directory).read_segment(0)
    np.testing.assert_array_equal(fresh[name],
                                  np.full(fresh[name].shape, 7.5, np.float32))
    eng.close()
    assert eng.stats()["bytes_written"] == store.seg_nbytes[0]


def test_flush_writes_resident_dirty_segments(tmp_path):
    store = SegmentStore.create(str(tmp_path / "s"), _groups(), 2)
    eng = OffloadEngine(store, max_resident=2, prefetch=False)
    d1 = eng.acquire(1)
    name = next(iter(d1))
    d1[name][...] = -3.0
    eng.mark_dirty(1)
    eng.flush()
    fresh = SegmentStore.open(store.directory).read_segment(1)
    np.testing.assert_array_equal(fresh[name],
                                  np.full(fresh[name].shape, -3.0, np.float32))
    eng.close()


def test_prefetch_hits_and_window_cap(tmp_path):
    store = SegmentStore.create(str(tmp_path / "s"), _groups(n=8), 8)
    eng = OffloadEngine(store, max_resident=2, prefetch=True)
    eng.prefetch(0)
    for seg in range(8):
        eng.prefetch(seg + 1)
        eng.acquire(seg)
    s = eng.stats()
    eng.close()
    assert s["prefetch_hits"] > 0
    assert s["peak_resident_bytes"] < store.total_bytes


# ---------------------------------------------------------------------------
# copy-on-write snapshot (zero-copy checkpointing)
# ---------------------------------------------------------------------------
def test_snapshot_is_isolated_from_later_writes(tmp_path):
    store = SegmentStore.create(str(tmp_path / "s"), _groups(), 2)
    before = {n: a.copy() for s in range(2)
              for n, a in store.read_segment(s).items()}
    snap = store.snapshot(str(tmp_path / "snap"))
    name = store.segment_names(0)[0]
    store.write_segment(0, {name: np.zeros(store.record(name).shape,
                                           np.float32)})
    snap_store = SegmentStore.open(snap)
    for seg in range(2):
        for n, arr in snap_store.read_segment(seg).items():
            np.testing.assert_array_equal(arr, before[n])
    # ... while the live store sees the write
    np.testing.assert_array_equal(store.read_segment(0)[name], 0.0)


def test_link_clone_cow_isolates_source(tmp_path):
    store = SegmentStore.create(str(tmp_path / "s"), _groups(), 2)
    clone = SegmentStore.link_clone(store.directory, str(tmp_path / "c"))
    name = clone.segment_names(0)[0]
    orig = store.read_segment(0)[name].copy()
    clone.write_segment(0, {name: orig + 1.0})
    np.testing.assert_array_equal(store.read_segment(0)[name], orig)
    np.testing.assert_array_equal(clone.read_segment(0)[name], orig + 1.0)


# ---------------------------------------------------------------------------
# segment-wise AdamW
# ---------------------------------------------------------------------------
def _toy_params():
    k = jax.random.PRNGKey(0)
    return {"w1": jax.random.normal(k, (16, 8)),
            "b": jnp.zeros((8,)),
            "nest": {"w2": jax.random.normal(jax.random.fold_in(k, 1),
                                             (8, 4))}}


def test_offloaded_update_matches_adamw(tmp_path):
    params = _toy_params()
    state = {"params": params, "opt": adamw_init(params),
             "step": jnp.zeros((), jnp.int32)}
    ost = OffloadedTrainState.create(state, str(tmp_path / "o"), 3)
    p_mem, opt_mem = params, adamw_init(params)
    for step in range(3):           # multi-step: count / bias correction
        grads = jax.tree.map(lambda p: jnp.ones_like(p) * 0.1 * (step + 1),
                             params)
        p_mem, opt_mem = adamw_update(grads, opt_mem, p_mem, lr=1e-2)
        p_off = ost.apply_update(grads, lr=1e-2)
        jax.tree.map(lambda a, b: np.testing.assert_allclose(
            a, b, rtol=1e-4, atol=1e-5), p_mem, p_off)
    ost.flush()
    assert ost.count == 3
    # moments round-trip through the segment files
    ost2 = OffloadedTrainState.open(ost.store.directory, params)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        a, b, rtol=1e-4, atol=1e-5), p_mem, ost2.materialize_params())
    assert ost2.count == 3
    ost.close()
    ost2.close()


def test_offload_checkpoint_save_restore(tmp_path):
    params = _toy_params()
    state = {"params": params, "opt": adamw_init(params),
             "step": jnp.zeros((), jnp.int32)}
    ost = OffloadedTrainState.create(state, str(tmp_path / "work"), 2)
    grads = jax.tree.map(jnp.ones_like, params)
    p1 = ost.apply_update(grads, lr=1e-2)
    ckdir = str(tmp_path / "ckpt")
    save_offload(ost, ckdir, ost.step, keep=2)
    assert latest_step(ckdir) == 1
    assert is_offload_checkpoint(ckdir, 1)
    # keep training past the snapshot — checkpoint must not move
    ost.apply_update(grads, lr=1e-2)
    ost.flush()
    re, step = restore_offload(ckdir, str(tmp_path / "work2"), params)
    assert step == 1 and re.count == 1
    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b, rtol=1e-5),
                 p1, re.materialize_params())
    ost.close()
    re.close()


def test_offload_resident_bytes_analytic():
    specs = registry.param_specs(configs.get_smoke("gpt2_124m"))
    full, res = offload_resident_bytes(specs, num_segments=8, window=2)
    assert res < full
    _, res_more_segs = offload_resident_bytes(specs, num_segments=32,
                                              window=2)
    assert res_more_segs < res      # more segments -> smaller window share


# ---------------------------------------------------------------------------
# smoke-train equivalence (acceptance criterion)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("steps", [4])
def test_smoke_train_offload_matches_in_memory(tmp_path, steps):
    from repro.launch.train import train_loop
    cfg = configs.get_smoke("gpt2_124m")
    base = dict(global_batch=4, seq_len=32, microbatches=1,
                learning_rate=1e-4, total_steps=steps, warmup_steps=1,
                compute_dtype="float32")
    t_mem = TrainConfig(**base)
    t_off = TrainConfig(**base, offload_segments=4,
                        offload_dir=str(tmp_path / "segs"))
    _, obs_mem = train_loop(cfg, t_mem, out_dir=None, print_fn=None)
    _, obs_off = train_loop(cfg, t_off, out_dir=None, print_fn=None)
    losses_mem = [r["loss"] for r in obs_mem.rows]
    losses_off = [r["loss"] for r in obs_off.rows]
    np.testing.assert_allclose(losses_mem, losses_off, atol=1e-3)
    # offloaded state on disk equals full (p, m, v) footprint
    st = SegmentStore.open(str(tmp_path / "segs"))
    n_params = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(
        registry.param_specs(cfg), is_leaf=lambda x: hasattr(x, "axes")))
    assert st.total_bytes == n_params * 12   # fp32 p + m + v
