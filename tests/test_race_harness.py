"""Race-harness tests: pinned PR 5 bug replays (fail pre-fix, pass
current), a seeded slice of every fuzz scenario, the shutdown-ordering
satellites, and the harness's own machinery (watchdog, fuzzed
primitives, ownership detectors).

The full >= 200-interleavings-per-scenario sweep runs in CI via
``python -m tools.repro_analysis.race --quick``; here each scenario gets
a handful of seeds so tier-1 stays fast.
"""
import threading
import time

import numpy as np
import pytest

from tools.repro_analysis import race, replays
from tools.repro_analysis.schedules import (DeadlockError, FuzzedCondition,
                                            FuzzedLock, Schedule,
                                            fuzzed_primitives,
                                            run_with_watchdog)

TEST_SEEDS = range(4)


# ---------------------------------------------------------------------------
# pinned PR 5 replays: deterministic fail on pre-fix, pass on current
# ---------------------------------------------------------------------------

def test_replay_pool_indexerror(tmp_path):
    replays.replay_pool_indexerror(str(tmp_path / "pre"), pre_fix=True)
    replays.replay_pool_indexerror(str(tmp_path / "cur"), pre_fix=False)


def test_replay_silent_writer_death(tmp_path):
    replays.replay_silent_writer_death(str(tmp_path / "pre"), pre_fix=True)
    replays.replay_silent_writer_death(str(tmp_path / "cur"), pre_fix=False)


def test_replay_take_overdrop(tmp_path):
    replays.replay_take_overdrop(str(tmp_path / "pre"), pre_fix=True)
    replays.replay_take_overdrop(str(tmp_path / "cur"), pre_fix=False)


# ---------------------------------------------------------------------------
# seeded scenario slices (the CI job runs the full sweep)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(race.SCENARIOS))
def test_scenario_seeded_slice(name):
    for seed in TEST_SEEDS:
        race.run_scenario(name, seed, watchdog_s=60.0)


# the two shutdown-ordering satellites, called out explicitly so a failure
# names the contract rather than a scenario slug

def test_streamed_base_close_with_inflight_stage_future(tmp_path):
    for seed in TEST_SEEDS:
        race.scenario_close_inflight_stage(seed, str(tmp_path / str(seed)))


def test_engine_close_with_nonempty_write_queue(tmp_path):
    for seed in TEST_SEEDS:
        race.scenario_close_pending_writes(seed, str(tmp_path / str(seed)))


# ---------------------------------------------------------------------------
# harness machinery
# ---------------------------------------------------------------------------

def test_watchdog_flags_deadlock_with_stacks():
    release = threading.Event()
    with pytest.raises(DeadlockError) as ei:
        run_with_watchdog(lambda: release.wait(30.0), timeout_s=0.3,
                          label="hang")
    assert "thread" in str(ei.value)     # the stack dump is attached
    release.set()                        # unpark the leaked worker


def test_watchdog_propagates_scenario_exceptions():
    def boom():
        raise ValueError("scenario assertion")
    with pytest.raises(ValueError, match="scenario assertion"):
        run_with_watchdog(boom, timeout_s=5.0)


def test_schedule_is_seed_deterministic():
    def decisions(seed):
        sched = Schedule(seed)
        out = []
        for _ in range(64):
            rng = sched._rng()
            out.append(rng.random())
        return out
    assert decisions(7) == decisions(7)
    assert decisions(7) != decisions(8)


def test_fuzzed_primitives_patch_and_restore():
    real_cond, real_lock = threading.Condition, threading.Lock
    sched = Schedule(0)
    with fuzzed_primitives(sched):
        c = threading.Condition()
        lk = threading.Lock()
        assert isinstance(c, FuzzedCondition)
        assert isinstance(lk, FuzzedLock)
        with lk:
            pass
        with c:
            c.notify_all()
    assert threading.Condition is real_cond
    assert threading.Lock is real_lock
    assert sched.points > 0


def test_fuzzed_condition_bounds_waits():
    sched = Schedule(3)
    with fuzzed_primitives(sched):
        c = threading.Condition()
    t0 = time.perf_counter()
    with c:
        woke = c.wait()                  # nobody notifies: spurious wakeup
    assert not woke
    assert time.perf_counter() - t0 < 5.0


# ---------------------------------------------------------------------------
# ownership detectors (satellite audit: engine window + adapter cache)
# ---------------------------------------------------------------------------

def test_engine_window_rejects_concurrent_entry(tmp_path):
    store = replays.make_store(str(tmp_path / "s"), n_segments=3)
    eng = race.OffloadEngine(store, max_resident=2, prefetch=False)
    gate_in, gate_out = threading.Event(), threading.Event()
    orig = eng._writeback

    def slow_writeback(seg, data):
        gate_in.set()
        gate_out.wait(10.0)
        return orig(seg, data)

    eng._writeback = slow_writeback
    errs = []

    def owner():
        eng.acquire(0)
        eng.acquire(1)
        eng.acquire(2)                   # evicts -> parks in slow_writeback

    t = threading.Thread(target=owner, daemon=True)  # thread-ok: joined below, failure surfaces via the asserts
    t.start()
    assert gate_in.wait(10.0)
    try:
        with pytest.raises(RuntimeError, match="single-owner"):
            eng.acquire(0)               # second thread mid-window-call
    finally:
        gate_out.set()
        t.join(10.0)
    assert not t.is_alive()
    eng._writeback = orig
    eng.close()                          # ownership transferred back: fine


def test_adapter_cache_rejects_concurrent_get(tmp_path):
    from repro.serve.adapters import AdapterCache
    cache = AdapterCache.__new__(AdapterCache)  # contract check only
    cache._cache = {}
    cache._owner = None
    cache.hits = 0
    gate_in, gate_out = threading.Event(), threading.Event()

    from collections import OrderedDict

    class _Gate(OrderedDict):
        def get(self, k, default=None):
            gate_in.set()
            gate_out.wait(10.0)
            return OrderedDict.get(self, k, default)

    cache._cache = _Gate({"a": object()})
    out = {}

    def first():
        out["tree"] = cache.get("a")

    t = threading.Thread(target=first, daemon=True)  # thread-ok: joined below, out["tree"] asserted
    t.start()
    assert gate_in.wait(10.0)
    try:
        with pytest.raises(RuntimeError, match="single-threaded"):
            cache.get("a")
    finally:
        gate_out.set()
        t.join(10.0)
    assert out["tree"] is not None
    assert cache.get("a") is out["tree"]  # owner released: works again


# ---------------------------------------------------------------------------
# checkpoint-store satellite: async save errors surface on wait()
# ---------------------------------------------------------------------------

def test_checkpoint_save_async_error_surfaces(tmp_path, monkeypatch):
    from repro.checkpoint import store as ckpt_store
    cs = ckpt_store.CheckpointStore(str(tmp_path / "ckpt"))

    def bad_save(*a, **k):
        raise OSError("disk full")

    monkeypatch.setattr(ckpt_store, "save", bad_save)
    cs.save_async({"w": np.zeros(2, np.float32)}, step=1)
    with pytest.raises(RuntimeError, match="async checkpoint write"):
        cs.wait()
    cs.wait()                            # error consumed: second wait clean
