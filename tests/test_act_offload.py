"""Activation-boundary offload (long-seq streaming; repro/offload/act_store.py).

Covers: fp32 spill bit-identity vs the device-resident streamed path (dense
and ssm, micro-batching on and off), activation-codec round-trip bounds
(bf16 / per-token int8), reverse-order prefetch hit rate on a direct
6-boundary walk, loss tracking under the int8 activation codec, resume
determinism with the spill enabled, the seq-len-aware analytic resident
bound, and flash-vs-ref attention fwd/bwd equivalence (the Pallas kernel
against its streaming numerics oracle).
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.config import TrainConfig
from repro.core.attention import attention
from repro.core.step import init_state, make_stream_step
from repro.core.zero import stream_resident_bytes
from repro.launch.train import train_loop
from repro.models import registry
from repro.offload import ActivationStore, LayerStreamedState
from repro.offload.codecs import activation_codec, get_codec


def _batch(cfg, batch=4, seq=32, seed=1):
    b = registry.make_batch(jax.random.PRNGKey(seed), cfg, batch, seq)
    b["labels"] = b["tokens"]
    return b


def _stream_losses(arch, tmp_path, tag, steps=10, micro=1, **extra):
    cfg = configs.get_smoke(arch)
    tcfg = TrainConfig(global_batch=4, seq_len=32, learning_rate=1e-4,
                       microbatches=micro, total_steps=steps, warmup_steps=1,
                       compute_dtype="float32", offload_stream_params=True,
                       offload_resident=2, **extra)
    state = init_state(jax.random.PRNGKey(0), cfg, tcfg)
    batch = _batch(cfg)
    lstate = LayerStreamedState.create(state, str(tmp_path / f"{tag}-segs"),
                                       max_resident=2)
    step_fn = make_stream_step(cfg, tcfg, lstate,
                               str(tmp_path / f"{tag}-grads"))
    losses = []
    try:
        for s in range(steps):
            loss, _ = step_fn(batch, s)
            losses.append(float(loss))
    finally:
        step_fn.close()
        lstate.close()
    return losses


# ---------------------------------------------------------------------------
# fp32 spill is bit-identical to the device-resident streamed path
# (acceptance criterion: exact equality over 10 steps, dense + ssm)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["gpt2_124m", "mamba2_130m"])
@pytest.mark.parametrize("micro", [1, 2])
def test_fp32_spill_bit_identical(arch, micro, tmp_path):
    resident = _stream_losses(arch, tmp_path, "res", micro=micro)
    spilled = _stream_losses(arch, tmp_path, "act", micro=micro,
                             offload_activations=True,
                             activation_codec="fp32")
    assert spilled == resident  # bit-exact, not allclose


# ---------------------------------------------------------------------------
# lossy activation codecs: bounded loss tracking (not bit-equality)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("codec", ["bf16", "int8"])
def test_lossy_codec_tracks_loss(codec, tmp_path):
    resident = _stream_losses("gpt2_124m", tmp_path, "res")
    spilled = _stream_losses("gpt2_124m", tmp_path, codec,
                             offload_activations=True,
                             activation_codec=codec)
    np.testing.assert_allclose(spilled, resident, atol=1e-2)


# ---------------------------------------------------------------------------
# codec round-trip bounds (pure host-side numerics)
# ---------------------------------------------------------------------------
def test_activation_codec_mapping():
    assert activation_codec("fp32") == "identity"
    assert activation_codec("") == "identity"
    assert activation_codec("bf16") == "bf16"
    assert activation_codec("int8") == "act_int8"
    with pytest.raises(ValueError):
        activation_codec("fp8")


def test_bf16_roundtrip_bound():
    rng = np.random.default_rng(0)
    x = rng.standard_normal((4, 16, 8), dtype=np.float32) * 10.0
    y = get_codec("bf16").storage_roundtrip(x)
    # bf16 has 8 mantissa bits -> relative error <= 2^-8
    np.testing.assert_allclose(y, x, rtol=2 ** -8, atol=0)


def test_act_int8_roundtrip_per_token():
    rng = np.random.default_rng(1)
    # outlier tokens: per-token absmax must localize the damage
    x = rng.standard_normal((4, 16, 8), dtype=np.float32)
    x[0, 0] *= 100.0                       # one hot token
    y = get_codec("act_int8").storage_roundtrip(x)
    absmax = np.abs(x).max(axis=-1, keepdims=True)
    # symmetric int8: error <= half a quantization step per *token*
    assert np.all(np.abs(y - x) <= absmax / 127.0 * 0.5 + 1e-7)
    # the outlier token's scale did not leak into other tokens
    tame = np.abs(y[1:] - x[1:]).max()
    assert tame <= np.abs(x[1:]).max(axis=-1).max() / 127.0 * 0.5 + 1e-7


def test_act_int8_encoded_bytes_per_token():
    codec = get_codec("act_int8")
    x = np.zeros((3, 5, 8), np.float32)
    # 1 byte/element + one fp32 scale per (batch, position)
    assert codec.encoded_nbytes(x.shape, "float32") == 3 * 5 * 8 + 3 * 5 * 4


# ---------------------------------------------------------------------------
# reverse-order prefetch: a direct 6-boundary walk must be served almost
# entirely from the write queue + prefetch buffers (hit rate >= 0.9)
# ---------------------------------------------------------------------------
def test_reverse_walk_hit_rate(tmp_path):
    n, shape = 6, (4, 32, 8)
    rng = np.random.default_rng(2)
    acts = [rng.standard_normal(shape).astype(np.float32) for _ in range(n)]
    store = ActivationStore(str(tmp_path / "acts"), n, shape)
    try:
        for i in range(n):                 # forward sweep sinks in order
            # sink takes ownership of the array (the writer may pool it as
            # a reusable read destination) — keep pristine reference copies
            store.sink(i, acts[i].copy())
        store.barrier()                    # writes landed -> prefetchable
        store.prefetch(n - 1)
        for i in reversed(range(n)):       # backward sweep: reverse order
            if i > 0:
                store.prefetch(i - 1)
            got = store.take(i)
            np.testing.assert_array_equal(got, acts[i])
            store.recycle(i, got)
        assert store.hit_rate() >= 0.9, store.stats()
        s = store.stats()
        assert s["takes"] == n
        assert s["bytes_sunk"] == n * acts[0].nbytes
    finally:
        store.close()


def test_take_before_sink_raises(tmp_path):
    store = ActivationStore(str(tmp_path / "acts"), 2, (2, 3))
    try:
        with pytest.raises(KeyError):
            store.take(1)
        with pytest.raises(ValueError):
            store.sink(0, np.zeros((9, 9), np.float32))
    finally:
        store.close()


def test_take_is_consume_once(tmp_path):
    """A dirty steal hands over bytes that never landed on flash, so a
    second take of the same boundary would read whatever older spill the
    file holds — the store must refuse it until the boundary is re-sunk
    (the race harness's act_store_churn scenario caught the stale read)."""
    store = ActivationStore(str(tmp_path / "acts"), 2, (2, 3))
    try:
        store.sink(0, np.full((2, 3), 1.0, np.float32))
        store.barrier()
        store.sink(0, np.full((2, 3), 2.0, np.float32))  # queued, not landed
        got = store.take(0)                  # dirty steal of the 2.0 bytes
        np.testing.assert_array_equal(got, 2.0)
        with pytest.raises(KeyError):
            store.take(0)                    # file still holds 1.0
        store.sink(0, np.full((2, 3), 3.0, np.float32))
        np.testing.assert_array_equal(store.take(0), 3.0)  # re-sink re-arms
    finally:
        store.close()


def test_resink_overwrites(tmp_path):
    """Micro-batch 2 re-sinks every boundary; takes must see the new bytes
    even when the first sink's prefetch lookahead was never consumed."""
    store = ActivationStore(str(tmp_path / "acts"), 3, (2, 4))
    try:
        old = [np.full((2, 4), i, np.float32) for i in range(3)]
        new = [np.full((2, 4), 10 + i, np.float32) for i in range(3)]
        for i in range(3):
            store.sink(i, old[i].copy())
        store.barrier()
        store.prefetch(2)                  # stale lookahead
        for i in range(3):
            store.sink(i, new[i].copy())   # must invalidate it
        for i in reversed(range(3)):
            got = store.take(i)
            np.testing.assert_array_equal(got, new[i])
            store.recycle(i, got)
    finally:
        store.close()


# ---------------------------------------------------------------------------
# resume determinism with the spill enabled
# ---------------------------------------------------------------------------
def test_resume_determinism_with_act_offload(tmp_path):
    cfg = configs.get_smoke("gpt2_124m")
    base = dict(global_batch=2, seq_len=16, learning_rate=1e-4,
                schedule="constant", warmup_steps=1, compute_dtype="float32",
                offload_stream_params=True, offload_activations=True,
                activation_codec="fp32")
    tA = TrainConfig(**base, total_steps=6)
    _, oA = train_loop(cfg, tA, out_dir=None, print_fn=None)
    out = str(tmp_path / "run")
    tB1 = TrainConfig(**base, total_steps=3, checkpoint_every=3)
    _, oB1 = train_loop(cfg, tB1, out_dir=out, print_fn=None)
    tB2 = TrainConfig(**base, total_steps=6, checkpoint_every=3)
    _, oB2 = train_loop(cfg, tB2, out_dir=out, print_fn=None)
    assert oB2.rows[0]["step"] == 3
    lossesA = [r["loss"] for r in oA.rows]
    lossesB = ([r["loss"] for r in oB1.rows] + [r["loss"] for r in oB2.rows])
    np.testing.assert_allclose(lossesA, lossesB, atol=1e-6)


# ---------------------------------------------------------------------------
# seq-len-aware analytic bound: offloaded acts are depth-independent
# ---------------------------------------------------------------------------
def test_stream_resident_bytes_act_term():
    # full-depth config: the spill wins once n_layers + 1 boundaries exceed
    # its O(window) buffer share (a 2-layer smoke config can't show that)
    cfg = configs.get("gpt2_124m")
    specs = registry.param_specs(cfg)
    kw = dict(window=2, write_queue=4, batch=4, seq_len=4096,
              d_model=cfg.d_model)
    _, no_off = stream_resident_bytes(specs, **kw)
    _, off = stream_resident_bytes(specs, act_offload=True, **kw)
    _, base = stream_resident_bytes(specs, window=2, write_queue=4)
    # device-resident acts pin L+1 boundaries; the spill holds O(window)
    assert no_off - base == (cfg.n_layers + 1) * 4 * 4096 * cfg.d_model * 4
    assert off < no_off
    # the offloaded act term does not grow with depth
    assert (off - base) == (1 + (2 + 1 + 2)) * 4 * 4096 * cfg.d_model * 4
    # bf16 storage halves the spill share (not the live fp32 boundary)
    _, off_bf16 = stream_resident_bytes(specs, act_offload=True, act_bytes=2,
                                        **kw)
    assert off_bf16 < off


# ---------------------------------------------------------------------------
# flash (Pallas) vs ref (streaming oracle): fwd/bwd equivalence on CPU
# (interpret mode is auto-gated by the dispatcher on the cpu backend)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kvh", [4, 2], ids=["mha", "gqa"])
def test_flash_matches_ref_fwd_bwd(kvh):
    b, s, h, d = 2, 64, 4, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kvh, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kvh, d), jnp.float32)
    w = jax.random.normal(ks[3], (b, s, h, d), jnp.float32)

    def loss(impl):
        def f(q, k, v):
            o = attention(q, k, v, causal=True, impl=impl, chunk=32)
            return jnp.sum(o * w)
        return jax.value_and_grad(f, argnums=(0, 1, 2))(q, k, v)

    l_ref, g_ref = loss("ref")
    l_fl, g_fl = loss("flash")
    np.testing.assert_allclose(float(l_fl), float(l_ref), rtol=2e-5,
                               atol=2e-4)
    for gr, gf in zip(g_ref, g_fl):
        np.testing.assert_allclose(np.asarray(gf), np.asarray(gr),
                                   rtol=2e-4, atol=2e-4)
